"""Elastic fleet tests (DESIGN.md §14): the crash-consistency matrix over
the four migration/failover crash points on every engine, split/merge
round trips, epoch-stamped re-dispatch, auto-triggering, and the replica
golden-parity contract after ``fail_primary``.

The crash matrix is the lockdown: arm one of the new fleet crash points,
drive a split (or failover) into it, and require ``ShardedStore.open`` to
recover a fleet whose contents are byte-identical to the latest-write
oracle — no lost key, no resurrected delete, no duplicated move — on all
seven engines.  Migrations are *derived* work (never journaled), so
recovery replays the user-op stream and re-derives them; the matrix is
what makes that argument load-bearing.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import ENGINES, EngineConfig, ShardedStore, Store, WriteBatch
from repro.core.durability import CrashPoint, manifest_summary
from repro.core.durability.wal import replay_into

KEY_SPACE = 4096
VSIZES = (64, 600, 2000)

TINY = dict(memtable_bytes=8 << 10, ksst_bytes=8 << 10, vsst_bytes=32 << 10,
            base_level_bytes=16 << 10, cache_bytes=16 << 10,
            dropcache_keys=64, sep_threshold=256, max_levels=5)

MIGRATION_POINTS = ("mid_migration_copy", "pre_reroute", "mid_delta_replay")


def _cfg(engine, **kw):
    return EngineConfig(engine=engine, **TINY, **kw)


def _workload(fleet, oracle, rng, rounds=6, n=64, deletes=True):
    """Mixed put/delete rounds against the fleet, mirrored into a
    latest-write-wins dict oracle."""
    for r in range(rounds):
        ks = rng.integers(0, KEY_SPACE, n).astype(np.uint64)
        vs = rng.choice(VSIZES, n).astype(np.int64)
        b = WriteBatch().puts(ks, vs)
        for k, v in zip(ks.tolist(), vs.tolist()):
            oracle[k] = v
        if deletes and r % 2 == 1 and oracle:
            dks = rng.choice(np.fromiter(oracle, np.uint64,
                                         count=len(oracle)),
                             min(8, len(oracle)), replace=False)
            for k in dks.tolist():
                b.delete(k)
                oracle.pop(k, None)
        fleet.write(b)


def _assert_oracle(fleet, oracle):
    """Fleet contents must match the oracle exactly: every live key found
    with its latest vsize, every deleted key absent."""
    assert oracle, "workload produced an empty oracle"
    ks = np.fromiter(sorted(oracle), np.uint64, count=len(oracle))
    res = fleet.multi_get(ks)
    assert res["found"].all(), \
        f"lost keys: {ks[~res['found']][:10].tolist()}"
    want = np.array([oracle[int(k)] for k in ks.tolist()], np.int64)
    assert (res["vsize"] == want).all()
    dead = np.setdiff1d(np.arange(KEY_SPACE, dtype=np.uint64), ks)
    if len(dead):
        probe = dead[:: max(1, len(dead) // 64)]
        assert not fleet.multi_get(probe)["found"].any(), \
            "resurrected deleted/never-written keys"


# ===================================================== crash matrix (§14)

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("point", MIGRATION_POINTS)
def test_crash_matrix_split(tmp_path, engine, point):
    """Crash inside each split phase; recovery must re-derive the
    migration from the journal and land oracle-exact."""
    rng = np.random.default_rng(11)
    oracle = {}
    fleet = ShardedStore(_cfg(engine), n_shards=2, key_space=KEY_SPACE,
                         durability_dir=tmp_path / "fleet")
    _workload(fleet, oracle, rng, rounds=4)
    fleet.checkpoint()
    _workload(fleet, oracle, rng, rounds=3)
    fleet.arm_crash(point)
    with pytest.raises(CrashPoint):
        fleet.split_shard(0)
    fleet.close()

    rec = ShardedStore.open(tmp_path / "fleet")
    _assert_oracle(rec, oracle)
    summary = manifest_summary(tmp_path / "fleet" / "MANIFEST")
    assert summary["kinds"]["fleet_checkpoint"] >= 1
    assert summary["kinds"].get("migration_begin", 0) >= 1
    # the recovered fleet keeps working: more writes, then a clean split
    _workload(rec, oracle, rng, rounds=2)
    _assert_oracle(rec, oracle)
    rec.close()


@pytest.mark.parametrize("point", MIGRATION_POINTS)
def test_crash_matrix_merge(tmp_path, point):
    """Same matrix through the merge path (victim drain + retire)."""
    rng = np.random.default_rng(13)
    oracle = {}
    fleet = ShardedStore(_cfg("scavenger"), n_shards=3,
                         key_space=KEY_SPACE,
                         durability_dir=tmp_path / "fleet")
    _workload(fleet, oracle, rng, rounds=4)
    fleet.checkpoint()
    _workload(fleet, oracle, rng, rounds=2)
    fleet.arm_crash(point)
    with pytest.raises(CrashPoint):
        fleet.merge_shards(1)
    fleet.close()

    rec = ShardedStore.open(tmp_path / "fleet")
    _assert_oracle(rec, oracle)
    _workload(rec, oracle, rng, rounds=2)
    _assert_oracle(rec, oracle)
    rec.close()


@pytest.mark.parametrize("engine", ENGINES)
def test_crash_matrix_pre_promote(tmp_path, engine):
    """Crash at the promotion edge: the primary is still the recovered
    machine, and post-recovery failover works on the re-seeded replicas."""
    rng = np.random.default_rng(17)
    oracle = {}
    fleet = ShardedStore(_cfg(engine, replica_count=1, replica_lag_ops=4),
                         n_shards=2, key_space=KEY_SPACE,
                         durability_dir=tmp_path / "fleet")
    _workload(fleet, oracle, rng, rounds=4)
    fleet.checkpoint()
    _workload(fleet, oracle, rng, rounds=2)
    fleet.arm_crash("pre_promote")
    with pytest.raises(CrashPoint):
        fleet.fail_primary(0)
    fleet.close()

    rec = ShardedStore.open(tmp_path / "fleet")
    _assert_oracle(rec, oracle)
    _workload(rec, oracle, rng, rounds=2)
    rec.fail_primary(0)              # re-seeded replicas can promote
    _assert_oracle(rec, oracle)
    summary = manifest_summary(tmp_path / "fleet" / "MANIFEST")
    assert summary["kinds"].get("replica_promote", 0) >= 1
    rec.close()


def test_crash_recovery_after_completed_split(tmp_path):
    """Checkpoint *after* a split: recovery restores the split topology
    (router state + per-shard-id snapshots) instead of re-deriving it."""
    rng = np.random.default_rng(19)
    oracle = {}
    fleet = ShardedStore(_cfg("scavenger"), n_shards=2,
                         key_space=KEY_SPACE,
                         durability_dir=tmp_path / "fleet")
    _workload(fleet, oracle, rng, rounds=4)
    assert fleet.split_shard(0) is not None
    epoch = fleet.router.epoch
    fleet.checkpoint()
    _workload(fleet, oracle, rng, rounds=2)
    fleet.close()

    rec = ShardedStore.open(tmp_path / "fleet")
    assert len(rec.shards) == 3
    assert rec.router.epoch >= epoch
    assert rec.router.state_dict()["cuts"][-1] == KEY_SPACE
    _assert_oracle(rec, oracle)
    rec.close()


# ============================================== split/merge round trips

@pytest.mark.parametrize("engine", ENGINES)
def test_split_then_merge_roundtrip(engine):
    """Explicit split then merge back: oracle intact, vids preserved
    across the move, scans ordered across the new boundaries, epoch
    strictly monotone."""
    rng = np.random.default_rng(23)
    oracle = {}
    fleet = ShardedStore(_cfg(engine), n_shards=2, key_space=KEY_SPACE)
    _workload(fleet, oracle, rng, rounds=5)
    ks = np.fromiter(sorted(oracle), np.uint64, count=len(oracle))
    before = fleet.multi_get(ks)

    new_pos = fleet.split_shard(0)
    assert new_pos is not None
    assert fleet.router.epoch == 1
    assert len(fleet.shards) == 3
    after = fleet.multi_get(ks)
    assert after["found"].all()
    # migration preserves value identity, not just size
    assert (after["vid"] == before["vid"]).all()
    assert (after["vsize"] == before["vsize"]).all()

    got = fleet.multi_scan(np.array([0], np.int64), 200)[0]
    keys_only = [k for k, _ in got]
    assert keys_only == sorted(keys_only)
    assert keys_only == sorted(oracle)[:len(got)]

    assert fleet.merge_shards(new_pos)
    assert fleet.router.epoch == 2
    assert len(fleet.shards) == 2
    _assert_oracle(fleet, oracle)
    got = fleet.multi_scan(np.array([0], np.int64), 200)[0]
    keys_only = [k for k, _ in got]
    assert keys_only == sorted(oracle)[:len(got)]

    st = fleet.stats()
    assert st["n_migrations"] == 2
    assert st["router_epoch"] == 2
    kinds = [m["kind"] for m in fleet.migrations]
    assert kinds == ["split", "merge"]
    assert all(m["fence_us"] >= 0.0 for m in fleet.migrations)
    assert fleet.migrated_bytes() > 0


def test_hash_policy_split_merge_roundtrip():
    """Splits cut the *hashed* domain: fan-out scans stay correct and the
    oracle survives a hash-slice round trip."""
    rng = np.random.default_rng(29)
    oracle = {}
    fleet = ShardedStore(_cfg("scavenger"), n_shards=2,
                         shard_policy="hash")
    _workload(fleet, oracle, rng, rounds=5)
    new_pos = fleet.split_shard(1)
    assert new_pos is not None
    _assert_oracle(fleet, oracle)
    got = fleet.multi_scan(np.array([0], np.int64), 100)[0]
    assert [k for k, _ in got] == sorted(oracle)[:len(got)]
    assert fleet.merge_shards(new_pos)
    _assert_oracle(fleet, oracle)


def test_split_empty_shard_returns_none():
    fleet = ShardedStore(_cfg("rocksdb"), n_shards=2, key_space=KEY_SPACE)
    assert fleet.split_shard(0) is None
    assert fleet.router.epoch == 0
    assert len(fleet.shards) == 2


# ======================================= epoch fencing & re-dispatch

def test_epoch_bump_mid_batch_redispatches(monkeypatch):
    """Force a split to finalize between two shard sub-batches of one
    WriteBatch: the epoch-stamped worklist must detect the bump and
    re-route the unwritten rows — nothing lost, nothing written twice."""
    rng = np.random.default_rng(31)
    oracle = {}
    fleet = ShardedStore(_cfg("scavenger"), n_shards=2,
                         key_space=KEY_SPACE)
    _workload(fleet, oracle, rng, rounds=4, deletes=False)

    fired = {"done": False}
    orig = ShardedStore._shard_write

    def hook(self, pos, kinds, keys, vsizes):
        vids = orig(self, pos, kinds, keys, vsizes)
        if not fired["done"]:
            fired["done"] = True
            self.split_shard(1)      # epoch bump with rows still pending
        return vids

    monkeypatch.setattr(ShardedStore, "_shard_write", hook)
    ks = np.arange(0, KEY_SPACE, 16).astype(np.uint64)   # spans both shards
    vs = np.full(len(ks), 600, np.int64)
    fleet.write(WriteBatch().puts(ks, vs))
    monkeypatch.setattr(ShardedStore, "_shard_write", orig)
    for k, v in zip(ks.tolist(), vs.tolist()):
        oracle[k] = v

    assert fired["done"]
    assert fleet.redispatches >= 1
    assert len(fleet.shards) == 3
    _assert_oracle(fleet, oracle)


def test_auto_split_trigger():
    """A hot shard crossing elastic_split_frac gets split automatically
    at op boundaries; the fleet grows toward elastic_max_shards and the
    hot shard's space share drops."""
    cfg = _cfg("scavenger", elastic_split_frac=0.6,
               elastic_cooldown_ops=256, elastic_max_shards=4,
               migration_chunk_records=64)
    fleet = ShardedStore(cfg, n_shards=2, key_space=KEY_SPACE)
    assert fleet.elastic.auto
    rng = np.random.default_rng(37)
    oracle = {}
    for _ in range(30):              # hammer shard 0's slice
        ks = rng.integers(0, KEY_SPACE // 4, 64).astype(np.uint64)
        vs = rng.choice(VSIZES, 64).astype(np.int64)
        fleet.write(WriteBatch().puts(ks, vs))
        for k, v in zip(ks.tolist(), vs.tolist()):
            oracle[k] = v
    fleet.drain()                    # quiesce any in-flight migration
    assert len(fleet.shards) > 2
    assert len(fleet.shards) <= cfg.elastic_max_shards
    assert fleet.stats()["n_migrations"] >= 1
    assert fleet.router.epoch >= 1
    _assert_oracle(fleet, oracle)


def test_auto_merge_drains_cold_shard():
    """A shard whose space/traffic share falls below elastic_merge_frac
    is drained into a neighbor and retired."""
    cfg = _cfg("scavenger", elastic_merge_frac=0.05,
               elastic_cooldown_ops=256, migration_chunk_records=64)
    fleet = ShardedStore(cfg, n_shards=3, key_space=KEY_SPACE)
    rng = np.random.default_rng(41)
    oracle = {}
    lo = KEY_SPACE // 3              # shard 0's slice stays cold
    for _ in range(20):
        ks = rng.integers(lo, KEY_SPACE, 64).astype(np.uint64)
        vs = rng.choice(VSIZES, 64).astype(np.int64)
        fleet.write(WriteBatch().puts(ks, vs))
        for k, v in zip(ks.tolist(), vs.tolist()):
            oracle[k] = v
    fleet.drain()
    assert len(fleet.shards) < 3
    assert any(m["kind"] == "merge" for m in fleet.migrations)
    assert len(fleet.retired) >= 1
    _assert_oracle(fleet, oracle)
    # retired history still counts in fleet aggregates
    assert fleet.user_write_bytes >= sum(s.user_write_bytes
                                         for s in fleet.shards)


def test_elasticity_off_is_inert():
    """Default config: no ElasticityManager activity, epoch pinned at 0,
    no redispatches — the fleet behaves exactly like the pre-elastic
    ShardedStore (n_shards=1 ≡ Store parity is locked down in
    test_sharding.py)."""
    cfg = _cfg("scavenger")
    assert cfg.elastic_split_frac is None
    assert cfg.elastic_merge_frac == 0.0
    assert cfg.replica_count == 0
    fleet = ShardedStore(cfg, n_shards=2, key_space=KEY_SPACE)
    assert not fleet.elastic.auto
    rng = np.random.default_rng(43)
    oracle = {}
    _workload(fleet, oracle, rng, rounds=6)
    fleet.drain()
    assert fleet.router.epoch == 0
    assert fleet.redispatches == 0
    assert fleet.migrations == []
    assert fleet.replicators == {}
    st = fleet.stats()
    assert st["n_migrations"] == 0 and st["router_epoch"] == 0
    _assert_oracle(fleet, oracle)


# ====================================== replication & failover (§14)

@pytest.mark.parametrize("engine", ("scavenger", "titan"))
def test_failover_promoted_replica_matches_golden_replay(engine):
    """The golden-parity contract: after ``fail_primary`` mid-workload,
    the promoted replica is byte-identical — full stats dict, vid
    watermark, oracle contents — to a fresh Store that replayed the same
    replication log (vid minting and scheduling are pure functions of the
    per-shard op stream)."""
    cfg = _cfg(engine, replica_count=2, replica_lag_ops=8)
    fleet = ShardedStore(cfg, n_shards=2, key_space=KEY_SPACE)
    rng = np.random.default_rng(47)
    oracle = {}
    _workload(fleet, oracle, rng, rounds=5)
    # mixed read/scan traffic replicates too (clock parity needs it)
    ks = np.fromiter(sorted(oracle), np.uint64, count=len(oracle))
    fleet.multi_get(ks[:64])
    fleet.multi_scan(np.array([0], np.int64), 50)

    prim = fleet.shards[0]
    rep = fleet.replicators[prim.shard_id]
    assert rep.applied[0] >= rep.applied[1]      # rank 0 lags least
    log_copy = list(rep.log)
    prim_cfg = prim.cfg

    promoted = fleet.fail_primary(0)
    assert promoted is fleet.shards[0]
    assert promoted is not prim

    golden = Store(dataclasses.replace(prim_cfg, observer=None))
    replay_into(golden, log_copy)
    assert golden.stats() == promoted.stats()
    assert golden.next_vid == promoted.next_vid
    gks = np.fromiter(sorted(oracle), np.uint64, count=len(oracle))
    on_shard = gks[fleet.router.shard_of(gks) == 0]
    if len(on_shard):
        g = golden.multi_get(on_shard)
        p = promoted.multi_get(on_shard)
        assert (g["found"] == p["found"]).all()
        assert (g["vid"] == p["vid"]).all()

    # the fleet keeps serving through the promoted primary
    _assert_oracle(fleet, oracle)
    _workload(fleet, oracle, rng, rounds=2)
    _assert_oracle(fleet, oracle)


def test_fail_primary_without_replicas_raises():
    fleet = ShardedStore(_cfg("rocksdb"), n_shards=2, key_space=KEY_SPACE)
    with pytest.raises(ValueError, match="no replicas"):
        fleet.fail_primary(0)


def test_replica_lag_bounds_applied_positions():
    """Rank r trails the log tail by r * replica_lag_ops records until a
    promotion replays the remainder."""
    cfg = _cfg("scavenger", replica_count=3, replica_lag_ops=5)
    fleet = ShardedStore(cfg, n_shards=1, key_space=KEY_SPACE)
    rng = np.random.default_rng(53)
    _workload(fleet, {}, rng, rounds=4, deletes=False)
    rep = fleet.replicators[fleet.shards[0].shard_id]
    n = len(rep.log)
    assert rep.applied == [max(0, n - r * 5) for r in range(3)]
    assert rep.best() == 0
