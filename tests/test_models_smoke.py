"""Per-architecture smoke tests: reduced config, one forward + train-grad
step + one decode step on CPU; asserts shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import build_model


def make_batch(cfg, rng, batch=2, seq=16):
    tok = rng.integers(0, cfg.vocab, (batch, seq)).astype(np.int32)
    if cfg.enc_dec:
        return {"frames": jnp.asarray(
                    rng.standard_normal((batch, seq, cfg.d_model)),
                    jnp.float32),
                "tokens": jnp.asarray(tok)}
    if cfg.modality == "vlm":
        p = min(cfg.n_patches, 8)
        return {"patches": jnp.asarray(
                    rng.standard_normal((batch, p, cfg.d_model)),
                    jnp.float32),
                "tokens": jnp.asarray(tok)}
    return {"tokens": jnp.asarray(tok)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = make_batch(cfg, rng)

    logits = jax.jit(model.forward)(params, batch)
    s_total = batch["tokens"].shape[1] + (
        batch["patches"].shape[1] if "patches" in batch else 0)
    assert logits.shape == (2, s_total, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # at least some gradient signal
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(1))
    b, cache_len = 2, 32
    enc_len = 16 if cfg.enc_dec else 0
    cache = model.init_cache(b, cache_len, enc_len=enc_len)
    if cfg.enc_dec:
        # populate the cross cache via prefill
        rng = np.random.default_rng(1)
        batch = make_batch(cfg, rng, b, 8)
        _, cache_pre = jax.jit(
            lambda p, bt: model.prefill(p, bt, cache_len=cache_len)
        )(params, batch)
        cache = cache_pre
        start = 8
    else:
        start = 0
    step = jax.jit(model.serve_step)
    tok = jnp.ones((b, 1), jnp.int32)
    for pos in range(start, start + 3):
        logits, cache = step(params, cache,
                             {"token": tok, "pos": jnp.int32(pos)})
        assert logits.shape == (b, 1, cfg.vocab_padded)
        assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["smollm_360m", "qwen2_05b",
                                  "llava_next_mistral_7b"])
def test_prefill_matches_decode(arch):
    """Prefill then one decode step == forward over the longer sequence."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(2))
    rng = np.random.default_rng(2)
    b, s = 2, 8
    batch = make_batch(cfg, rng, b, s)
    logits_pre, cache = jax.jit(
        lambda p, bt: model.prefill(p, bt, cache_len=32))(params, batch)
    next_tok = jnp.asarray(rng.integers(0, cfg.vocab, (b, 1)), jnp.int32)
    p_off = batch["patches"].shape[1] if "patches" in batch else 0
    logits_dec, _ = jax.jit(model.serve_step)(
        params, cache, {"token": next_tok, "pos": jnp.int32(s + p_off)})

    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], next_tok], axis=1)
    logits_full = jax.jit(model.forward)(params, batch2)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(logits_full[:, -2], np.float32), rtol=2e-3, atol=2e-3)


def test_param_counts_match_public_sizes():
    """Analytic param counts should land near the names' billions."""
    expect = {
        "smollm_360m": (0.36e9, 0.25),
        "qwen15_05b": (0.62e9, 0.25),      # qwen1.5-0.5b is 620M actual
        "qwen2_05b": (0.49e9, 0.25),
        "stablelm_16b": (1.6e9, 0.25),
        "phi35_moe": (42e9, 0.20),
        "arctic_480b": (480e9, 0.15),
        "jamba_15_large": (398e9, 0.20),
        "xlstm_125m": (0.125e9, 0.40),
    }
    for arch, (want, tol) in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < tol, \
            f"{arch}: {got/1e9:.2f}B vs expected {want/1e9:.2f}B"


def test_sub_quadratic_flags():
    assert get_config("jamba_15_large").sub_quadratic
    assert get_config("xlstm_125m").sub_quadratic
    assert get_config("llava_next_mistral_7b").sub_quadratic  # SWA
    for a in ["smollm_360m", "qwen2_05b", "stablelm_16b", "phi35_moe",
              "arctic_480b", "whisper_base"]:
        assert not get_config(a).sub_quadratic, a
