"""§Perf attention variants must match the naive oracle exactly."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.layers import CHUNK_KV
from repro.models.model import build_model


def _variants(cfg):
    return {
        "grouped": dataclasses.replace(cfg, gqa_grouped=True),
        "chunked": dataclasses.replace(cfg, attn_impl="chunked"),
    }


@pytest.mark.parametrize("arch", ["smollm_360m", "qwen2_05b",
                                  "llava_next_mistral_7b"])
def test_forward_equivalence(arch):
    base_cfg = dataclasses.replace(
        get_config(arch, smoke=True), dtype="float32")
    model = build_model(base_cfg)
    params = model.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    seq = 2 * CHUNK_KV + 64 if arch != "llava_next_mistral_7b" else 128
    batch = {"tokens": jnp.asarray(
        rng.integers(0, base_cfg.vocab, (1, seq)), jnp.int32)}
    if base_cfg.modality == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((1, 8, base_cfg.d_model)), jnp.float32)
    ref = np.asarray(jax.jit(model.forward)(params, batch), np.float32)
    for name, cfg in _variants(base_cfg).items():
        m2 = build_model(cfg)
        got = np.asarray(jax.jit(m2.forward)(params, batch), np.float32)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4,
                                   err_msg=f"{arch}/{name}")


def test_chunked_window_attention_matches():
    cfg = dataclasses.replace(get_config("llava_next_mistral_7b",
                                         smoke=True),
                              dtype="float32", window=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(1))
    rng = np.random.default_rng(1)
    seq = 2 * CHUNK_KV + 32
    batch = {"patches": jnp.asarray(
                 rng.standard_normal((1, 4, cfg.d_model)), jnp.float32),
             "tokens": jnp.asarray(
                 rng.integers(0, cfg.vocab, (1, seq)), jnp.int32)}
    ref = np.asarray(build_model(dataclasses.replace(
        cfg, attn_impl="naive")).forward(params, batch), np.float32)
    got = np.asarray(build_model(dataclasses.replace(
        cfg, attn_impl="chunked")).forward(params, batch), np.float32)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_chunked_decode_matches():
    cfg = dataclasses.replace(get_config("qwen2_05b", smoke=True),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(2))
    b, cache_len = 1, 4 * CHUNK_KV
    tok = jnp.ones((b, 1), jnp.int32)
    outs = {}
    for name, c2 in [("naive", cfg),
                     ("chunked", dataclasses.replace(
                         cfg, attn_impl="chunked")),
                     ("grouped", dataclasses.replace(
                         cfg, gqa_grouped=True))]:
        m2 = build_model(c2)
        cache = m2.init_cache(b, cache_len)
        logits, _ = jax.jit(m2.serve_step)(
            params, cache, {"token": tok, "pos": jnp.int32(0)})
        outs[name] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs["chunked"], outs["naive"],
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs["grouped"], outs["naive"],
                               rtol=2e-4, atol=2e-4)
