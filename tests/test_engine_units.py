"""Unit tests for the storage-engine substrate."""

import numpy as np
import pytest

from repro.core.engine import (BloomFilter, BlockCache, DropCache,
                               EngineConfig, Memtable, SSTable, build_vsst,
                               splitmix64, hash_family)
from repro.core.engine.tables import (ETYPE_INLINE, ETYPE_REF, ETYPE_TOMB,
                                      _block_layout)


# --------------------------------------------------------------------- keys
def test_splitmix64_deterministic_and_spread():
    x = np.arange(1000, dtype=np.uint64)
    h1 = splitmix64(x)
    h2 = splitmix64(x)
    assert np.array_equal(h1, h2)
    assert len(np.unique(h1)) == 1000          # no collisions on tiny input
    # bits look balanced
    ones = sum(bin(int(v)).count("1") for v in h1) / (1000 * 64)
    assert 0.45 < ones < 0.55


def test_hash_family_shape_and_independence():
    keys = np.arange(64, dtype=np.uint64)
    hs = hash_family(keys, 5)
    assert hs.shape == (5, 64)
    assert not np.array_equal(hs[0], hs[1])


def test_bloom_no_false_negatives():
    keys = np.sort(np.unique(
        np.random.default_rng(0).integers(0, 1 << 60, 5000).astype(np.uint64)))
    bf = BloomFilter(keys, bits_per_key=10)
    assert bf.may_contain(keys).all()


def test_bloom_false_positive_rate_reasonable():
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 1 << 60, 4096).astype(np.uint64)
    bf = BloomFilter(np.sort(np.unique(keys)), bits_per_key=10)
    probes = rng.integers(1 << 61, 1 << 62, 10000).astype(np.uint64)
    fp = bf.may_contain(probes).mean()
    assert fp < 0.05          # ~1% expected at 10 bits/key


def test_bloom_empty():
    bf = BloomFilter(np.array([], np.uint64))
    assert not bf.may_contain(np.array([1, 2, 3], np.uint64)).any()


# -------------------------------------------------------------------- cache
def test_block_cache_two_priority_eviction():
    c = BlockCache(100, high_pri_frac=0.5)
    c.put(("f", 1), 40, BlockCache.PRI_HIGH)
    c.put(("f", 2), 40, BlockCache.PRI_LOW)
    c.put(("f", 3), 40, BlockCache.PRI_LOW)    # evicts low-pri first
    assert c.get(("f", 1))                      # high-pri survived
    assert not c.get(("f", 2))
    assert c.get(("f", 3))


def test_block_cache_erase_file():
    c = BlockCache(1000)
    c.put((1, "d", 0), 10)
    c.put((1, "d", 1), 10, BlockCache.PRI_HIGH)
    c.put((2, "d", 0), 10)
    c.erase_file(1)
    assert not c.get((1, "d", 0)) and not c.get((1, "d", 1))
    assert c.get((2, "d", 0))


def test_block_cache_oversized_item_ignored():
    c = BlockCache(100)
    c.put(("big",), 1000)
    assert c.used == 0


def test_dropcache_lru_and_hotness():
    d = DropCache(capacity_keys=3)
    d.record(np.array([1, 2, 3], np.uint64))
    d.record(np.array([4], np.uint64))          # evicts 1
    hot = d.is_hot(np.array([1, 2, 3, 4], np.uint64))
    assert list(hot) == [False, True, True, True]
    assert d.nbytes == 3 * DropCache.BYTES_PER_KEY


# ----------------------------------------------------------------- memtable
def test_memtable_overwrite_and_bytes():
    cfg = EngineConfig(engine="scavenger", memtable_bytes=1 << 20)
    mt = Memtable(cfg)
    mt.put(5, 1, 100, 1000)
    b1 = mt.bytes
    mt.put(5, 2, 101, 2000)                    # overwrite: bytes adjust
    assert mt.bytes == b1 + 1000
    assert mt.get(5)[2] == 101
    mt.delete(5, 3)
    assert mt.get(5)[1] == ETYPE_TOMB
    keys, seqs, ety, vids, vsz, vf = mt.sorted_arrays()
    assert len(keys) == 1 and ety[0] == ETYPE_TOMB


def test_memtable_sorted_arrays_order():
    cfg = EngineConfig(engine="rocksdb")
    mt = Memtable(cfg)
    for k in [9, 3, 7, 1]:
        mt.put(k, k, k, 10)
    keys, *_ = mt.sorted_arrays()
    assert list(keys) == [1, 3, 7, 9]


# ------------------------------------------------------------------- tables
def _mk_table(cfg, n=100, layout=None, kind="k"):
    keys = np.arange(0, 2 * n, 2, dtype=np.uint64)
    seqs = np.arange(n, dtype=np.uint64)
    ety = np.where(np.arange(n) % 3 == 0, ETYPE_REF,
                   ETYPE_INLINE).astype(np.uint8)
    vids = np.arange(n, dtype=np.uint64) + 1000
    vsz = np.full(n, 600, np.int64)
    vf = np.where(ety == ETYPE_REF, 7, -1).astype(np.int64)
    return SSTable(cfg, kind, layout or cfg.ksst_layout, keys, seqs, ety,
                   vids, vsz, vf)


def test_block_layout_assignment():
    rec = np.full(10, 1000, np.int64)
    bo, nb, bb = _block_layout(rec, 4096)
    assert nb == 3
    assert bb.sum() == 10_000
    assert bo[0] == 0 and bo[-1] == 2


def test_btable_find_and_ranges():
    cfg = EngineConfig(engine="terarkdb")
    t = _mk_table(cfg, 100)
    pos = t.find(np.array([0, 2, 3, 198], np.uint64))
    assert list(pos) == [0, 1, -1, 99]
    assert t.min_key == 0 and t.max_key == 198
    r = t.positions_in_range(10, 20)
    assert list(t.keys[r]) == [10, 12, 14, 16, 18, 20]


def test_dtable_separates_streams():
    cfg = EngineConfig(engine="scavenger")
    t = _mk_table(cfg, 99)
    assert t.layout == "dtable"
    assert t.n_kf_blocks >= 1 and t.n_kv_blocks >= 1
    # KF records are small: far more refs per block than inline records
    kf_per_block = t.kf_mask.sum() / t.n_kf_blocks
    kv_per_block = (~t.kf_mask).sum() / t.n_kv_blocks
    assert kf_per_block > kv_per_block


def test_rtable_dense_index_bigger_than_sparse():
    cfg_r = EngineConfig(engine="scavenger")
    cfg_b = EngineConfig(engine="terarkdb")
    n = 500
    keys = np.arange(n, dtype=np.uint64)
    vids = keys + 1
    vsz = np.full(n, 1024, np.int64)
    rt = build_vsst(cfg_r, keys, keys, vids, vsz)
    bt = build_vsst(cfg_b, keys, keys, vids, vsz)
    assert rt.layout == "rtable" and bt.layout == "btable"
    assert rt.index_bytes > bt.index_bytes          # dense index overhead...
    overhead = (rt.file_bytes - bt.file_bytes) / bt.file_bytes
    assert overhead < 0.05                          # ...but <5% (Table I)
    assert rt.n_index_blocks >= 1


def test_table_rejects_unsorted_keys():
    cfg = EngineConfig(engine="rocksdb")
    with pytest.raises(AssertionError):
        SSTable(cfg, "k", "btable",
                np.array([5, 3], np.uint64), np.zeros(2, np.uint64),
                np.zeros(2, np.uint8), np.zeros(2, np.uint64),
                np.zeros(2, np.int64), np.zeros(2, np.int64))


def test_vsst_garbage_ratio():
    cfg = EngineConfig(engine="terarkdb")
    keys = np.arange(10, dtype=np.uint64)
    t = build_vsst(cfg, keys, keys, keys + 1, np.full(10, 1000, np.int64))
    assert t.garbage_ratio() == 0.0
    t.garbage_bytes = t.total_value_bytes // 2
    assert abs(t.garbage_ratio() - 0.5) < 1e-9
