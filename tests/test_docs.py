"""Docs audit: module docstrings cite real DESIGN sections.

Two lightweight invariants keep the docs honest as the codebase grows:

  * every public module under ``src/repro/core/`` opens with a docstring
    that cites its DESIGN.md section (``DESIGN.md §N``), so a reader can
    always jump from code to the architecture doc;
  * every ``DESIGN.md §N`` / ``DESIGN §N`` reference anywhere in the
    source tree, the benchmarks, or the README points at a section that
    actually exists (``## §N`` heading in DESIGN.md) — no stale
    references after a docs reshuffle.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
CORE = REPO / "src" / "repro" / "core"

_CITE = re.compile(r"DESIGN(?:\.md)?\s*§(\d+)")
_HEADING = re.compile(r"^## §(\d+)\b", re.M)


def design_sections() -> set[int]:
    return {int(m) for m in _HEADING.findall((REPO / "DESIGN.md").read_text())}


def core_modules() -> list[Path]:
    return sorted(p for p in CORE.rglob("*.py")
                  if not p.name.startswith("_") or p.name == "__init__.py")


def test_design_has_sections():
    secs = design_sections()
    assert secs == set(range(1, max(secs) + 1)), \
        f"DESIGN.md sections are not contiguous: {sorted(secs)}"
    assert 9 in secs, "DESIGN.md §9 (durability & recovery) is missing"


def test_every_core_module_cites_its_design_section():
    secs = design_sections()
    missing, stale = [], []
    for path in core_modules():
        doc = ast.get_docstring(ast.parse(path.read_text())) or ""
        cites = [int(m) for m in _CITE.findall(doc)]
        if not cites:
            missing.append(str(path.relative_to(REPO)))
        elif not all(c in secs for c in cites):
            stale.append((str(path.relative_to(REPO)), cites))
    assert not missing, f"core modules without a DESIGN § citation: {missing}"
    assert not stale, f"core modules citing nonexistent sections: {stale}"


def test_all_design_references_resolve():
    secs = design_sections()
    bad = []
    roots = [REPO / "src", REPO / "benchmarks", REPO / "tests",
             REPO / "README.md"]
    for root in roots:
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for path in files:
            for n in _CITE.findall(path.read_text()):
                if int(n) not in secs:
                    bad.append((str(path.relative_to(REPO)), int(n)))
    assert not bad, f"stale DESIGN § references: {bad}"
