"""Docs audit — thin wrapper over scavlint's docs-citation pass.

The invariants (core module docstrings cite their DESIGN.md section;
every ``DESIGN §N`` reference resolves; sections are contiguous) are
enforced by ``repro.analysis.passes.docs`` — see DESIGN.md §10.  This
test just runs that single pass over the whole tree so the rules hold in
``pytest`` runs even when ``make lint`` is skipped, and so the pass and
the test can never drift apart.
"""

from __future__ import annotations

from pathlib import Path

from repro.analysis import run_analysis
from repro.analysis.passes.docs import design_sections

REPO = Path(__file__).resolve().parent.parent


def test_design_has_sections():
    secs = design_sections(REPO)
    assert secs, "DESIGN.md is missing or has no '## §N' sections"
    assert 9 in secs, "DESIGN.md §9 (durability & recovery) is missing"
    assert 10 in secs, "DESIGN.md §10 (static invariants) is missing"


def test_docs_citation_pass_is_clean():
    res = run_analysis(["src", "benchmarks", "examples", "tests"],
                       root=REPO, select=["docs-citation"])
    msgs = [f.render() for f in res.parse_errors + res.findings]
    assert not res.failed, "\n".join(msgs)
