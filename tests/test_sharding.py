"""Sharding subsystem: 1-shard parity, N-shard routing correctness, and
fleet scheduling invariants.

The headline contract (ISSUE 2 acceptance): ``ShardedStore(n_shards=1)``
is *byte-identical* to a plain ``Store`` on all five engines — same vids,
stats, clocks, and scheduling decisions — because with one shard the fleet
scheduler's global ranking degenerates to exactly ``Store.pump``.  With N
shards the store must still behave like a dict under any interleaving
(read-your-writes through scatter/gather routing), and ``multi_scan`` must
return globally key-ordered results on both placement policies.
"""

import numpy as np
import pytest

from _hypothesis_support import HealthCheck, given, settings, st

from repro.core import ENGINES, EngineConfig, ShardedStore, Store, WriteBatch
from repro.core.sharding import make_router, scatter

PARITY_CFG = dict(
    memtable_bytes=512 << 10, ksst_bytes=32 << 10, vsst_bytes=64 << 10,
    base_level_bytes=64 << 10, cache_bytes=32 << 10, dropcache_keys=64,
    sep_threshold=256, max_levels=5, gc_garbage_ratio=0.1)

TINY_CFG = dict(
    memtable_bytes=8 << 10, ksst_bytes=8 << 10, vsst_bytes=32 << 10,
    base_level_bytes=16 << 10, cache_bytes=16 << 10, dropcache_keys=64,
    sep_threshold=256, max_levels=5)

PARITY_FIELDS = ("user_write_bytes", "space_amp", "stall_s", "s_index",
                 "write_amp", "read_bytes", "write_bytes", "n_compactions",
                 "n_gc_runs", "clock_s", "gc_time_s", "cache_hit_ratio")


def _stream(rounds=5, n=300, nkeys=120, seed=3):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, nkeys, n).astype(np.uint64),
             rng.choice([64, 600, 2000, 9000], n).astype(np.int64))
            for _ in range(rounds)]


@pytest.mark.parametrize("engine", ENGINES)
def test_one_shard_parity_byte_identical(engine):
    """ShardedStore(n_shards=1) == Store, byte for byte, GC active."""
    stream = _stream()
    s1 = Store(EngineConfig(engine=engine, **PARITY_CFG))
    s2 = ShardedStore(EngineConfig(engine=engine, **PARITY_CFG), n_shards=1)
    o1, o2 = {}, {}
    for ks, vs in stream:
        v1 = s1.write(WriteBatch().puts(ks, vs))
        o1.update(zip(ks.tolist(), v1.tolist()))
        s1.flush()
        v2 = s2.write(WriteBatch().puts(ks, vs))
        o2.update(zip(ks.tolist(), v2.tolist()))
        s2.flush()
    assert o1 == o2, "vid assignment diverged"
    st1, st2 = s1.stats(), s2.stats()
    for f in PARITY_FIELDS:
        assert st1[f] == st2[f], (f, st1[f], st2[f])
    if s1.cfg.gc_scheme in ("inherit", "writeback"):
        assert s1.n_gc_runs == s2.n_gc_runs > 0, "parity regime must GC"
    probe = np.arange(120, dtype=np.uint64)
    r1, r2 = s1.multi_get(probe), s2.multi_get(probe)
    np.testing.assert_array_equal(r1["found"], r2["found"])
    np.testing.assert_array_equal(r1["vid"], r2["vid"])
    assert s1.multi_scan(np.array([0, 40, 110]), 15) \
        == s2.multi_scan(np.array([0, 40, 110]), 15)


@pytest.mark.parametrize("policy", ["range", "hash"])
@pytest.mark.parametrize("engine", ["titan", "scavenger"])
def test_n_shard_read_your_writes(engine, policy):
    """4-shard churn with deletes: every multi_get/multi_scan observes all
    prior writes (scatter/gather routing, fleet-scheduled background)."""
    rng = np.random.default_rng(7)
    s = ShardedStore(EngineConfig(engine=engine, **TINY_CFG), n_shards=4,
                     shard_policy=policy, key_space=200)
    oracle = {}
    for _ in range(8):
        ks = rng.integers(0, 200, 80).astype(np.uint64)
        vs = rng.choice([64, 600, 4000], 80).astype(np.int64)
        vids = s.write(WriteBatch().puts(ks, vs))
        oracle.update(zip(ks.tolist(), vids.tolist()))
        dels = rng.integers(0, 200, 5).astype(np.uint64)
        s.write(WriteBatch().deletes(dels))
        for k in dels.tolist():
            oracle.pop(k, None)
        res = s.multi_get(np.arange(200, dtype=np.uint64))
        for k in range(200):
            got = int(res["vid"][k]) if res["found"][k] else None
            assert got == oracle.get(k), k
    s.flush()
    assert s.n_compactions > 0
    res = s.multi_get(np.arange(200, dtype=np.uint64))
    for k in range(200):
        got = int(res["vid"][k]) if res["found"][k] else None
        assert got == oracle.get(k), k


@pytest.mark.parametrize("policy", ["range", "hash"])
def test_n_shard_multi_scan_ordering(policy):
    """multi_scan returns globally key-ordered prefixes on both policies
    (range: spill into successor shards; hash: full fan-out + merge)."""
    rng = np.random.default_rng(11)
    s = ShardedStore(EngineConfig(engine="scavenger", **TINY_CFG),
                     n_shards=3, shard_policy=policy, key_space=150)
    oracle = {}
    for _ in range(5):
        ks = rng.integers(0, 150, 60).astype(np.uint64)
        vs = rng.choice([64, 600, 4000], 60).astype(np.int64)
        vids = s.write(WriteBatch().puts(ks, vs))
        oracle.update(zip(ks.tolist(), vids.tolist()))
    starts = np.array([0, 23, 49, 50, 51, 99, 100, 149], np.int64)
    counts = np.array([7, 60, 5, 5, 200, 1, 12, 3], np.int64)
    outs = s.multi_scan(starts, counts)
    for st_, c, out in zip(starts.tolist(), counts.tolist(), outs):
        exp = sorted(k for k in oracle if k >= st_)[:c]
        assert out == [(k, oracle[k]) for k in exp], (st_, c)
        keys_out = [k for k, _ in out]
        assert keys_out == sorted(keys_out)


ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["put", "put", "put", "del", "get", "scan"]),
        st.integers(min_value=0, max_value=60),       # key
        st.sampled_from([64, 200, 600, 2000, 9000]),  # value size
    ),
    min_size=20, max_size=150)


@pytest.mark.parametrize("policy", ["range", "hash"])
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy)
def test_sharded_store_matches_dict_oracle(policy, ops):
    s = ShardedStore(EngineConfig(engine="scavenger", **TINY_CFG),
                     n_shards=3, shard_policy=policy, key_space=61)
    oracle = {}
    for op, key, vsize in ops:
        if op == "put":
            oracle[key] = s.put(key, vsize)
        elif op == "del":
            oracle.pop(key, None)
            s.delete(key)
        elif op == "get":
            assert s.get(key) == oracle.get(key)
        else:
            got = s.scan(key, 10)
            expect_keys = sorted(k for k in oracle if k >= key)[:10]
            assert got == [(k, oracle[k]) for k in expect_keys]
    s.flush()
    for k in range(61):
        assert s.get(k) == oracle.get(k), f"key {k} mismatch after drain"
    assert dict(s.scan(0, 1000)) == oracle


def test_fleet_quota_enforced_fleet_wide():
    """With n_shards > 1 the space quota moves off the shards and is
    enforced globally: total space stays near the quota, no data lost."""
    ds = 128 << 10
    cfg = EngineConfig(engine="scavenger", space_quota_bytes=int(3.0 * ds),
                       **TINY_CFG)
    s = ShardedStore(cfg, n_shards=2, shard_policy="range", key_space=32)
    assert all(sh.cfg.space_quota_bytes is None for sh in s.shards)
    assert s.fleet.space_quota_bytes == cfg.space_quota_bytes
    oracle = {}
    rng = np.random.default_rng(1)
    for _ in range(400):
        k = int(rng.integers(0, 32))
        oracle[k] = s.put(k, 2000)
        assert s.space_bytes() <= cfg.space_quota_bytes * 1.25, \
            "fleet space should stay near the shared quota"
    s.flush()
    for k, v in oracle.items():
        assert s.get(k) == v


def test_fleet_starvation_aging_services_cold_shard():
    """A cold shard's pending GC must eventually be serviced even while a
    hot shard keeps producing higher-garbage candidates (aging reorders)."""
    s = ShardedStore(EngineConfig(engine="scavenger", gc_garbage_ratio=0.05,
                                  **TINY_CFG),
                     n_shards=2, shard_policy="range", key_space=100)
    rng = np.random.default_rng(0)
    for _ in range(6):
        hot = rng.integers(0, 50, 60).astype(np.uint64)       # shard 0
        cold = rng.integers(50, 100, 12).astype(np.uint64)    # shard 1
        sizes_h = np.full(60, 1500, np.int64)
        sizes_c = np.full(12, 1500, np.int64)
        s.write(WriteBatch().puts(hot, sizes_h))
        s.write(WriteBatch().puts(cold, sizes_c))
        s.flush()
    assert s.shards[0].n_gc_runs > 0
    assert s.shards[1].n_gc_runs > 0, "cold shard starved of GC service"


def test_router_scatter_gather_roundtrip():
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 1000, 500).astype(np.uint64)
    for policy in ("hash", "range"):
        router = make_router(policy, 4, key_space=1000)
        sid = router.shard_of(keys)
        assert sid.min() >= 0 and sid.max() < 4
        order, starts, ends = scatter(sid, 4)
        seen = np.zeros(len(keys), bool)
        for sh in range(4):
            rows = order[starts[sh]:ends[sh]]
            assert (sid[rows] == sh).all()
            # stable: original relative order preserved within a shard
            assert (np.diff(rows) > 0).all() or len(rows) <= 1
            seen[rows] = True
        assert seen.all(), "scatter must partition the batch exactly"


def test_range_router_overflow_keys_go_last_shard():
    router = make_router("range", 4, key_space=100)
    sid = router.shard_of(np.array([0, 24, 25, 99, 100, 10_000], np.uint64))
    assert sid.tolist() == [0, 0, 1, 3, 3, 3]


# ------------------------- elastic router topology properties (§14) ----
# The contract after ANY split/merge sequence: every live shard owns
# exactly one slice, cuts stay strictly ascending and end at the domain,
# every key routes to exactly one live shard, and the epoch is strictly
# monotone across topology changes.  A hypothesis version explores op
# sequences when the library is present; the seeded version always runs.

def _apply_topo(router, ops):
    """Apply a split/merge sequence the way ShardedStore does: a split
    hands the upper half to a freshly appended shard position; a merge
    retires the victim position and renumbers the survivors."""
    n_live = len(router.owners)
    epochs = [router.epoch]
    for kind, frac in ops:
        if kind == "split":
            pos = int(frac * n_live) % n_live
            lo, hi = router.shard_range(pos)
            if hi - lo < 2:
                continue
            router.split(pos, lo + (hi - lo) // 2, n_live)
            n_live += 1
        else:
            if n_live < 2:
                continue
            pos = int(frac * n_live) % n_live
            router.merge(pos, router.neighbors(pos)[0])
            router.renumber_removed(pos)
            n_live -= 1
        epochs.append(router.epoch)
    return n_live, epochs


def _check_router_invariants(router, n_live, keys):
    # exactly one slice per live shard, positions dense
    assert sorted(router.owners) == list(range(n_live))
    assert router.cuts == sorted(set(router.cuts))
    assert router.cuts[-1] == router.domain
    # every key routes to exactly one live shard...
    sid = router.shard_of(keys)
    assert sid.min() >= 0 and sid.max() < n_live
    # ...and lands inside its slice's bounds (last slice absorbs overflow)
    rv = router.route(keys)
    sl = router.slice_of(keys)
    lows = np.array([router.slice_bounds(j)[0]
                     for j in range(router.n_slices)], np.uint64)
    assert (rv >= lows[sl]).all()
    inner = sl < router.n_slices - 1
    if inner.any():
        # cuts[:-1] only: the final cut equals the domain (2^64 for hash),
        # which does not fit uint64 — and the last slice is hi-unbounded
        his = np.array(router.cuts[:-1], np.uint64)
        assert (rv[inner] < his[sl[inner]]).all()
    for pos in range(n_live):
        assert router.owners[router.slice_of_shard(pos)] == pos


@pytest.mark.parametrize("policy", ["range", "hash"])
def test_router_topology_invariants_seeded(policy):
    rng = np.random.default_rng(7)
    for _ in range(20):
        router = make_router(policy, int(rng.integers(1, 5)),
                             key_space=4096)
        ops = [("split" if rng.random() < 0.6 else "merge",
                float(rng.random())) for _ in range(int(rng.integers(1, 12)))]
        n_live, epochs = _apply_topo(router, ops)
        assert epochs == sorted(epochs)
        assert len(set(epochs)) == len(epochs), "epoch must bump per change"
        keys = rng.integers(0, 5000, 300).astype(np.uint64)
        _check_router_invariants(router, n_live, keys)


topo_ops = st.lists(
    st.tuples(st.sampled_from(["split", "split", "merge"]),
              st.floats(min_value=0.0, max_value=1.0)),
    min_size=1, max_size=12)


@pytest.mark.parametrize("policy", ["range", "hash"])
@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=topo_ops, seed=st.integers(min_value=0, max_value=1 << 16))
def test_router_topology_invariants(policy, ops, seed):
    router = make_router(policy, 2, key_space=4096)
    n_live, epochs = _apply_topo(router, ops)
    assert epochs == sorted(epochs)
    assert len(set(epochs)) == len(epochs)
    keys = np.random.default_rng(seed).integers(0, 5000, 300) \
        .astype(np.uint64)
    _check_router_invariants(router, n_live, keys)


def test_scan_spills_across_split_boundary():
    """Range scans must spill in *slice* order after a split appends a
    shard whose position no longer tracks key order."""
    s = ShardedStore(EngineConfig(engine="scavenger", **TINY_CFG),
                     n_shards=2, shard_policy="range", key_space=200)
    rng = np.random.default_rng(9)
    oracle = {}
    for _ in range(4):
        ks = rng.integers(0, 200, 80).astype(np.uint64)
        vs = rng.choice([64, 600], 80).astype(np.int64)
        vids = s.write(WriteBatch().puts(ks, vs))
        oracle.update(zip(ks.tolist(), vids.tolist()))
    assert s.split_shard(0, cut=50) is not None   # slices: [0,50)[50,100)[100,200)
    starts = np.array([0, 49, 50, 99, 100, 150], np.int64)
    counts = np.full(len(starts), 60, np.int64)
    for st_, out in zip(starts.tolist(), s.multi_scan(starts, counts)):
        exp = sorted(k for k in oracle if k >= st_)[:60]
        assert out == [(k, oracle[k]) for k in exp], f"start={st_}"


def test_bad_configs_raise():
    cfg = EngineConfig(engine="scavenger", **TINY_CFG)
    with pytest.raises(ValueError):
        ShardedStore(cfg, n_shards=2, shard_policy="range")  # no key_space
    with pytest.raises(ValueError):
        ShardedStore(cfg, n_shards=2, shard_policy="nope", key_space=100)
    with pytest.raises(ValueError):
        ShardedStore(cfg, n_shards=2, shard_policy="hash", scheduler="nope")
    with pytest.raises(ValueError):
        ShardedStore(cfg, n_shards=0)
