"""Durability & crash recovery (DESIGN.md §9).

The crash-point matrix is the §9 recovery contract made executable: for
every engine and every injected crash point, the recovered store's logical
state (latest vid per key) *and* every ``stats()`` byte counter must be
byte-identical to an uninterrupted reference run at the crash watermark.
Plus: ``n_shards=1`` fleet recovery is byte-identical to single-``Store``
recovery, fleet recovery with real sharding, durability-on runs cost zero
simulated time, MANIFEST encode/decode and WAL prefix-replay idempotence
hypothesis properties, torn-tail tolerance, and the serve-tier page-table
restore.
"""

from __future__ import annotations

import numpy as np
import pytest

from _hypothesis_support import HealthCheck, given, settings, st

from repro.core import (CrashPoint, EngineConfig, ENGINES, ShardedStore,
                        Store, WriteBatch)
from repro.core.durability import (CRASH_POINTS, Durability, ManifestWriter,
                                   VersionEdit, read_manifest, read_wal,
                                   replay_into)
from repro.core.durability.wal import WalWriter

N_KEYS = 4096
VSIZES = np.array([64, 200, 600, 2000, 9000], np.int64)

# Crash points that cannot fire for an engine (no standalone GC run).
_INAPPLICABLE = {
    "rocksdb": {"gc_pre_chain", "gc_post_chain"},
    "blobdb": {"gc_pre_chain", "gc_post_chain"},
}


def _cfg(engine: str) -> EngineConfig:
    return EngineConfig.scaled(engine, 8 << 20, est_keys=N_KEYS)


def _ops(n_groups: int = 8, seed: int = 7) -> list:
    """Deterministic mixed op stream: puts, deletes, reads per group."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_groups):
        keys = rng.integers(0, N_KEYS, 192).astype(np.uint64)
        sizes = VSIZES[rng.integers(0, len(VSIZES), 192)]
        out.append(("puts", keys, sizes))
        out.append(("dels", rng.integers(0, N_KEYS, 16).astype(np.uint64)))
        out.append(("get", rng.integers(0, N_KEYS, 64).astype(np.uint64)))
    return out


def _apply(store, op) -> None:
    if op[0] == "puts":
        store.write(WriteBatch().puts(op[1], op[2]))
    elif op[0] == "dels":
        store.write(WriteBatch().deletes(op[1]))
    else:
        store.multi_get(op[1])


_REF_CACHE: dict[tuple, tuple] = {}


def _reference(engine: str, n_applied: int) -> tuple:
    """(stats, found, vids) of an uninterrupted run of the first
    ``n_applied`` ops (memoized: several crash points land on the same
    watermark)."""
    key = (engine, n_applied)
    if key not in _REF_CACHE:
        ref = Store(_cfg(engine))
        for op in _ops()[:n_applied]:
            _apply(ref, op)
        st_ = ref.stats()
        res = ref.multi_get(np.arange(N_KEYS, dtype=np.uint64))
        _REF_CACHE[key] = (st_, res["found"].copy(), res["vid"].copy())
    return _REF_CACHE[key]


def _assert_matches_reference(recovered, engine: str, n_applied: int):
    want_stats, want_found, want_vids = _reference(engine, n_applied)
    got = recovered.stats()
    assert got == want_stats, {
        k: (got[k], want_stats[k]) for k in got if got[k] != want_stats[k]}
    res = recovered.multi_get(np.arange(N_KEYS, dtype=np.uint64))
    assert (res["found"] == want_found).all()
    assert (res["vid"] == want_vids).all()


# ========================================================== crash matrix
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("point", CRASH_POINTS)
def test_crash_matrix(engine, point, tmp_path):
    """Recovery after a crash at ``point`` is byte-identical to an
    uninterrupted run at the crash watermark (the §9 contract)."""
    if point in _INAPPLICABLE.get(engine, ()):
        pytest.skip(f"{engine} has no standalone GC run")
    store = Store(_cfg(engine), durability_dir=tmp_path)
    ops = _ops()
    crashed = False
    for i, op in enumerate(ops):
        if i == 8:
            store.checkpoint()          # recovery = snapshot + WAL tail
        if i == 12:
            store.arm_crash(point, hits=2)
        try:
            _apply(store, op)
        except CrashPoint:
            crashed = True
            break
    # the crash watermark is whatever actually reached the journal — with
    # a space quota, background crash points can fire inside
    # _write_pressure() BEFORE the batch is journaled, so never assume the
    # in-flight op made it
    applied = store.wal_index
    # some (engine, point) pairs only fire late or not at all in this
    # stream; a completed run still exercises recovery at the final
    # watermark
    recovered = Store.open(tmp_path)
    _assert_matches_reference(recovered, engine, applied)
    if not crashed:
        assert engine in ("rocksdb", "blobdb") or point != "after_wal", \
            f"crash point {point} unexpectedly never fired for {engine}"


def test_crash_without_checkpoint(tmp_path):
    """No checkpoint: recovery replays the whole journal from scratch."""
    store = Store(_cfg("scavenger"), durability_dir=tmp_path)
    store.arm_crash("gc_post_chain")
    for op in _ops():
        try:
            _apply(store, op)
        except CrashPoint:
            break
    recovered = Store.open(tmp_path)
    _assert_matches_reference(recovered, "scavenger", store.wal_index)


def test_recovered_store_stays_durable(tmp_path):
    """Post-recovery writes land in a fresh WAL segment: a second crash /
    reopen sees them too."""
    store = Store(_cfg("scavenger"), durability_dir=tmp_path)
    for op in _ops(2):
        _apply(store, op)
    r1 = Store.open(tmp_path)
    r1.write(WriteBatch().puts(np.array([1], np.uint64),
                               np.array([123], np.int64)))
    want_vid = r1.get(1)            # journaled: replayed on reopen too
    want_stats = r1.stats()
    r1.close()
    r2 = Store.open(tmp_path)
    assert r2.stats() == want_stats
    assert r2.get(1) == want_vid


def test_durability_costs_zero_simulated_time(tmp_path):
    """A durable run's stats are byte-identical to an in-memory run —
    journaling and MANIFEST edits never touch the simulated device."""
    plain = Store(_cfg("scavenger"))
    durable = Store(_cfg("scavenger"), durability_dir=tmp_path)
    for op in _ops(4):
        _apply(plain, op)
        _apply(durable, op)
    assert durable.stats() == plain.stats()


def test_checkpoint_roundtrip_standalone_file(tmp_path):
    """`Store.checkpoint(path)` / `Store.open(path)` round-trips all seven
    engines without a durability directory, tracker sketches included."""
    for engine in ENGINES:
        store = Store(_cfg(engine))
        for op in _ops(3):
            _apply(store, op)
        snap = tmp_path / f"{engine}.ckpt"
        store.checkpoint(snap)
        restored = Store.open(snap)
        assert restored.stats() == store.stats()
        tracker = getattr(store.strategy, "tracker", None)
        if tracker is not None:
            rt = restored.strategy.tracker
            assert rt.ops == tracker.ops
            assert (rt.writes.counts == tracker.writes.counts).all()
            assert (rt.lifetime.hist == tracker.lifetime.hist).all()


def test_arm_crash_validates_point():
    store = Store(_cfg("scavenger"))
    with pytest.raises(ValueError, match="unknown crash point"):
        store.arm_crash("nonsense")


# ========================================================= fleet recovery
def test_fleet_one_shard_recovery_matches_store(tmp_path):
    """n_shards=1 fleet recovery is byte-identical to Store recovery."""
    d1, d2 = tmp_path / "store", tmp_path / "fleet"
    s = Store(_cfg("scavenger"), durability_dir=d1)
    f = ShardedStore(_cfg("scavenger"), n_shards=1, durability_dir=d2)
    for i, op in enumerate(_ops(6)):
        if i == 8:
            s.checkpoint()
            f.checkpoint()
        if i == 12:
            s.arm_crash("mid_compaction")
            f.shards[0].arm_crash("mid_compaction")
        for t in (s, f):
            try:
                _apply(t, op)
            except CrashPoint:
                pass
    rs, rf = Store.open(d1), ShardedStore.open(d2)
    st_s, st_f = rs.stats(), rf.stats()
    shared = set(st_s) & set(st_f)
    assert {k: st_s[k] for k in shared} == {k: st_f[k] for k in shared}
    ks = np.arange(N_KEYS, dtype=np.uint64)
    g1, g2 = rs.multi_get(ks), rf.multi_get(ks)
    assert (g1["vid"] == g2["vid"]).all()


def test_fleet_crash_recovery(tmp_path):
    """3-shard fleet: crash on one shard mid-GC, recover the whole fleet
    byte-identical to an uninterrupted fleet run (scheduler state, fleet
    epoch, and all shard clocks included)."""
    s = ShardedStore(_cfg("scavenger"), n_shards=3, key_space=N_KEYS,
                     durability_dir=tmp_path)
    ops = _ops(8)
    for i, op in enumerate(ops):
        if i == 10:
            s.checkpoint()
        if i == 14:
            for shard in s.shards:
                shard.arm_crash("gc_pre_chain")
        try:
            _apply(s, op)
        except CrashPoint:
            break
    applied = s.wal_index               # the fleet-journal watermark
    recovered = ShardedStore.open(tmp_path)
    assert recovered.fleet.epoch == 1
    ref = ShardedStore(_cfg("scavenger"), n_shards=3, key_space=N_KEYS)
    for op in ops[:applied]:
        _apply(ref, op)
    assert recovered.stats() == ref.stats()
    ks = np.arange(N_KEYS, dtype=np.uint64)
    g1, g2 = recovered.multi_get(ks), ref.multi_get(ks)
    assert (g1["found"] == g2["found"]).all()
    assert (g1["vid"] == g2["vid"]).all()


def test_fleet_crash_mid_fleet_checkpoint(tmp_path):
    """A crash between the per-shard snapshots and the fleet_checkpoint
    edit must not pair the new shard snapshots with the old fleet
    watermark: recovery restores the snapshots the last *committed* fleet
    edit names and replays the WAL tail exactly once."""
    s = ShardedStore(_cfg("scavenger"), n_shards=2, key_space=N_KEYS,
                     durability_dir=tmp_path)
    ops = _ops(6)
    for op in ops[:6]:
        _apply(s, op)
    s.checkpoint()                      # committed fleet cut C1
    for op in ops[6:]:
        _apply(s, op)
    # simulate dying mid-ShardedStore.checkpoint: shard snapshots written,
    # fleet_checkpoint edit never appended
    for shard in s.shards:
        shard.durability.checkpoint(shard)
    s.close()
    recovered = ShardedStore.open(tmp_path)
    ref = ShardedStore(_cfg("scavenger"), n_shards=2, key_space=N_KEYS)
    for op in ops:
        _apply(ref, op)
    assert recovered.stats() == ref.stats()
    ks = np.arange(N_KEYS, dtype=np.uint64)
    g1, g2 = recovered.multi_get(ks), ref.multi_get(ks)
    assert (g1["vid"] == g2["vid"]).all()


def test_store_subclass_open_returns_subclass(tmp_path):
    """Store.open on a subclass yields the subclass on both recovery
    paths (fresh-replay and snapshot-restore)."""
    class MyStore(Store):
        pass

    d1, d2 = tmp_path / "ckpt", tmp_path / "nockpt"
    s1 = MyStore(_cfg("scavenger"), durability_dir=d1)
    _apply(s1, _ops(1)[0])
    s1.checkpoint()
    s1.close()
    assert type(MyStore.open(d1)) is MyStore          # snapshot restore
    s2 = MyStore(_cfg("scavenger"), durability_dir=d2)
    _apply(s2, _ops(1)[0])
    s2.close()
    assert type(MyStore.open(d2)) is MyStore          # fresh replay


# ================================================== torn-tail tolerance
def test_torn_manifest_and_wal_tails(tmp_path):
    """Recovery tolerates a writer that died mid-append: garbage tails on
    the MANIFEST and the live WAL segment are dropped."""
    store = Store(_cfg("scavenger"), durability_dir=tmp_path)
    ops = _ops(3)
    for op in ops:
        _apply(store, op)
    store.close()
    with open(tmp_path / "MANIFEST", "ab") as fh:
        fh.write(b"\x13torn-tail-garbage")
    wals = sorted(tmp_path.glob("wal-*.log"))
    with open(wals[-1], "ab") as fh:
        fh.write(b"\xff" * 7)
    recovered = Store.open(tmp_path)
    _assert_matches_reference(recovered, "scavenger", len(ops))


# ============================================== hypothesis round-trips
_json_scalars = st.one_of(st.integers(-2**53, 2**53), st.booleans(),
                          st.text(max_size=20), st.none())
_edit_strategy = st.builds(
    VersionEdit,
    kind=st.text(min_size=1, max_size=20),
    data=st.dictionaries(st.text(max_size=10),
                         st.one_of(_json_scalars,
                                   st.lists(_json_scalars, max_size=4)),
                         max_size=4))


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(edits=st.lists(_edit_strategy, max_size=20))
def test_manifest_roundtrip_property(edits, tmp_path):
    """Arbitrary VersionEdit sequences survive encode -> append -> decode."""
    path = tmp_path / f"MANIFEST-{abs(hash(str(edits))) % 997}"
    w = ManifestWriter(path)
    for e in edits:
        w.append(e)
    w.close()
    assert read_manifest(path) == edits
    path.unlink()


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(groups=st.lists(
    st.lists(st.tuples(st.integers(0, 255), st.integers(0, 4096)),
             min_size=1, max_size=32),
    min_size=1, max_size=6),
    prefix=st.integers(0, 6))
def test_wal_prefix_replay_idempotent(groups, prefix, tmp_path):
    """Replaying a WAL prefix twice equals replaying it once."""
    path = tmp_path / f"wal-{abs(hash(str(groups))) % 997}.log"
    w = WalWriter(path)
    seq = 0
    for i, g in enumerate(groups):
        keys = np.array([k for k, _ in g], np.uint64)
        sizes = np.array([s for _, s in g], np.int64)
        kinds = (sizes == 0).astype(np.uint8)     # vsize 0 -> delete
        w.append_batch(i + 1, seq + 1, kinds, keys,
                       np.where(kinds == 1, 0, sizes))
        seq += len(g)
    w.close()
    records = read_wal(path)[:prefix]
    once = Store(_cfg("scavenger"))
    replay_into(once, records)
    twice = Store(_cfg("scavenger"))
    replay_into(twice, records)
    replay_into(twice, records)               # second pass must no-op
    assert twice.stats() == once.stats()
    assert twice.seq == once.seq and twice.wal_index == once.wal_index
    path.unlink()


# ====================================================== serve-tier restore
def test_serve_page_table_restore(tmp_path):
    """ServeEngine.restore_page_tables rebuilds pager reservations from a
    recovered metadata store (admission records survive the crash,
    finished rids stay finished)."""
    from repro.serve.engine import ServeEngine
    from repro.serve.paged_cache import PagedKVCacheManager

    meta = Store(EngineConfig.scaled("scavenger", 4 << 20),
                 durability_dir=tmp_path)
    rids = np.array([11, 22, 33], np.uint64)
    meta.write(WriteBatch().puts(rids, np.array([4 * 16, 2 * 16, 8 * 16],
                                                np.int64)))
    meta.write(WriteBatch().deletes(np.array([22], np.uint64)))
    # crash: abandon `meta`, recover from its directory
    recovered = Store.open(tmp_path)

    eng = ServeEngine.__new__(ServeEngine)    # pager+meta are all the
    eng.meta = recovered                      # restore path touches
    eng.pager = PagedKVCacheManager(64, 16, extent_pages=4)
    restored = eng.restore_page_tables()
    assert restored == [11, 33]
    assert len(eng.pager.page_tables[11]) == 4
    assert len(eng.pager.page_tables[33]) == 8
    assert 22 not in eng.pager.page_tables


def test_refusing_to_recreate_existing_dir(tmp_path):
    Store(_cfg("scavenger"), durability_dir=tmp_path).close()
    with pytest.raises(FileExistsError):
        Store(_cfg("scavenger"), durability_dir=tmp_path)
    with pytest.raises(FileExistsError):
        Durability.create(tmp_path, _cfg("scavenger"))
