"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes/dtypes + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from _hypothesis_support import given, settings, st

from repro.kernels import (bloom_build, bloom_probe, bloom_probe_ref,
                           gc_lookup, gc_lookup_ref, hot_cold_partition,
                           hot_cold_partition_ref, merge_dedup,
                           merge_dedup_ref, page_gather, page_gather_ref)
from repro.kernels.common import bitonic_merge, bitonic_sort


# ------------------------------------------------------------- common nets
@pytest.mark.parametrize("n", [2, 8, 64, 256])
def test_bitonic_sort_matches_numpy(n):
    rng = np.random.default_rng(n)
    keys = rng.integers(0, 1000, n).astype(np.uint32)
    payload = np.arange(n, dtype=np.uint32)
    k, p = bitonic_sort(jnp.asarray(keys), jnp.asarray(payload))
    assert_array_equal(np.sort(keys), np.asarray(k))
    # payload follows its key
    assert_array_equal(keys[np.asarray(p)], np.asarray(k))


def test_bitonic_merge_of_two_sorted_runs():
    rng = np.random.default_rng(0)
    a = np.sort(rng.integers(0, 500, 32)).astype(np.uint32)
    b = np.sort(rng.integers(0, 500, 32)).astype(np.uint32)
    seq = np.concatenate([a, b[::-1]]).astype(np.uint32)
    (merged,) = bitonic_merge(jnp.asarray(seq))
    assert_array_equal(np.sort(np.concatenate([a, b])), np.asarray(merged))


# --------------------------------------------------------------- gc_lookup
@pytest.mark.parametrize("q,n", [(1, 10), (17, 100), (300, 1000),
                                 (256, 512), (5, 2000)])
def test_gc_lookup_matches_ref(q, n):
    rng = np.random.default_rng(q * 1000 + n)
    s_keys = np.sort(rng.choice(np.arange(1, 10 * n, dtype=np.uint32),
                                size=n, replace=False))
    s_vids = rng.integers(1, 1 << 30, n).astype(np.uint32)
    s_vf = rng.integers(1, 1 << 20, n).astype(np.uint32)
    queries = np.concatenate([
        rng.choice(s_keys, q // 2 + 1),
        rng.integers(10 * n, 20 * n, q - q // 2 - 1).astype(np.uint32)])[:q]
    got = gc_lookup(queries, s_keys, s_vids, s_vf)
    want = gc_lookup_ref(jnp.asarray(queries), jnp.asarray(s_keys),
                         jnp.asarray(s_vids), jnp.asarray(s_vf))
    for g, w in zip(got, want):
        assert_array_equal(np.asarray(g), np.asarray(w))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 2**20), min_size=1, max_size=200, unique=True),
       st.lists(st.integers(0, 2**20), min_size=1, max_size=100))
def test_gc_lookup_property(skeys, queries):
    s_keys = np.sort(np.array(skeys, np.uint32))
    s_vids = s_keys + 7
    s_vf = s_keys % 97
    q = np.array(queries, np.uint32)
    found, vid, vf = gc_lookup(q, s_keys, s_vids, s_vf)
    member = np.isin(q, s_keys)
    assert_array_equal(np.asarray(found), member)
    assert_array_equal(np.asarray(vid)[member], (q + 7)[member])


# ------------------------------------------------------------------- bloom
@pytest.mark.parametrize("n,q", [(10, 5), (1000, 300), (5000, 1000)])
def test_bloom_probe_matches_ref_and_no_false_negatives(n, q):
    rng = np.random.default_rng(n)
    keys = rng.choice(np.arange(1, 1 << 24, dtype=np.uint32), n,
                      replace=False)
    words, k, nbits = bloom_build(keys)
    probes = np.concatenate([keys[:q // 2],
                             rng.integers(1 << 24, 1 << 25,
                                          q - q // 2).astype(np.uint32)])
    got = np.asarray(bloom_probe(probes, words, k, nbits))
    want = np.asarray(bloom_probe_ref(jnp.asarray(probes), words, k, nbits))
    assert_array_equal(got, want)
    assert got[:q // 2].all(), "bloom false negative!"
    fp = got[q // 2:].mean()
    assert fp < 0.1


# ------------------------------------------------------------------- merge
@pytest.mark.parametrize("na,nb", [(1, 1), (10, 3), (100, 100), (64, 257)])
def test_merge_dedup_matches_ref(na, nb):
    rng = np.random.default_rng(na * 97 + nb)
    ak = np.sort(rng.choice(np.arange(1000, dtype=np.uint32), na,
                            replace=False))
    bk = np.sort(rng.choice(np.arange(1000, dtype=np.uint32), nb,
                            replace=False))
    aseq = rng.integers(0, 1000, na).astype(np.uint32) * 2        # even
    bseq = rng.integers(0, 1000, nb).astype(np.uint32) * 2 + 1    # odd
    avid = rng.integers(0, 1 << 30, na).astype(np.uint32)
    bvid = rng.integers(0, 1 << 30, nb).astype(np.uint32)
    gk, gs, gv, gkeep = merge_dedup(ak, aseq, avid, bk, bseq, bvid)
    wk, ws, wv, wkeep = merge_dedup_ref(
        jnp.asarray(ak), jnp.asarray(aseq), jnp.asarray(avid),
        jnp.asarray(bk), jnp.asarray(bseq), jnp.asarray(bvid))
    # compare surviving rows (sorted by key) — orderings within dup pairs
    # may differ, winners must not
    got = sorted(zip(np.asarray(gk)[np.asarray(gkeep)].tolist(),
                     np.asarray(gs)[np.asarray(gkeep)].tolist(),
                     np.asarray(gv)[np.asarray(gkeep)].tolist()))
    want = sorted(zip(np.asarray(wk)[np.asarray(wkeep)].tolist(),
                      np.asarray(ws)[np.asarray(wkeep)].tolist(),
                      np.asarray(wv)[np.asarray(wkeep)].tolist()))
    assert got == want
    # merged keys are sorted
    assert (np.diff(np.asarray(gk)) >= 0).all()


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=60, unique=True),
       st.lists(st.integers(0, 50), min_size=1, max_size=60, unique=True))
def test_merge_dedup_property_newest_wins(akeys, bkeys):
    ak = np.sort(np.array(akeys, np.uint32))
    bk = np.sort(np.array(bkeys, np.uint32))
    aseq = np.full(len(ak), 10, np.uint32)
    bseq = np.full(len(bk), 20, np.uint32)       # b is newer
    avid = ak + 1
    bvid = bk + 2
    gk, gs, gv, keep = merge_dedup(ak, aseq, avid, bk, bseq, bvid)
    kept = {int(k): int(v) for k, v in
            zip(np.asarray(gk)[np.asarray(keep)],
                np.asarray(gv)[np.asarray(keep)])}
    expect = {int(k): int(k) + 1 for k in ak}
    expect.update({int(k): int(k) + 2 for k in bk})   # newer b wins
    assert kept == expect


# --------------------------------------------------------------- partition
@pytest.mark.parametrize("n", [1, 7, 64, 500])
def test_partition_matches_ref(n):
    rng = np.random.default_rng(n)
    keys = rng.integers(0, 1 << 30, n).astype(np.uint32)
    hot = rng.random(n) < 0.3
    vids = rng.integers(0, 1 << 30, n).astype(np.uint32)
    vsz = rng.integers(1, 1 << 16, n).astype(np.uint32)
    gk, gv, gs, gcnt = hot_cold_partition(keys, hot, vids, vsz)
    wk, wv, ws, wcnt = hot_cold_partition_ref(
        jnp.asarray(keys), jnp.asarray(hot), jnp.asarray(vids),
        jnp.asarray(vsz))
    assert int(gcnt) == int(wcnt) == hot.sum()
    assert_array_equal(np.asarray(gk), np.asarray(wk))
    assert_array_equal(np.asarray(gv), np.asarray(wv))
    assert_array_equal(np.asarray(gs), np.asarray(ws))


# ------------------------------------------------------------ paged gather
@pytest.mark.parametrize("b,p,npages,psize,d,dtype", [
    (1, 1, 4, 8, 128, jnp.float32),
    (4, 8, 64, 16, 128, jnp.float32),
    (2, 4, 16, 8, 64, jnp.bfloat16),
    (3, 5, 32, 4, 256, jnp.int32),
])
def test_page_gather_matches_ref(b, p, npages, psize, d, dtype):
    rng = np.random.default_rng(b * 100 + p)
    pages = jnp.asarray(
        rng.standard_normal((npages, psize, d)) * 10).astype(dtype)
    table = rng.integers(0, npages, (b, p)).astype(np.int32)
    got = page_gather(table, pages)
    want = page_gather_ref(jnp.asarray(table), pages)
    assert got.shape == (b, p * psize, d)
    assert_array_equal(np.asarray(got.astype(jnp.float32)),
                       np.asarray(want.astype(jnp.float32)))
