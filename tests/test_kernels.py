"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes/dtypes + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from _hypothesis_support import given, settings, st

from repro.kernels import (bloom_build, bloom_probe, bloom_probe_ref,
                           gather_min64, gc_lookup, gc_lookup_ref,
                           hot_cold_partition, hot_cold_partition_ref,
                           interval_rank, lookup_probe, merge_dedup,
                           merge_dedup_ref, page_gather, page_gather_ref,
                           rank_probe, run_coalesce, segment_sum)
from repro.kernels.common import bitonic_merge, bitonic_sort

# kernels.lookup_probe / kernels.run_coalesce / kernels.segment_reduce ops
# run in both modes: the jitted XLA oracle and the Pallas interpreter.
MODES = ("xla", "interpret")

# largest u32 value the dispatchers accept (pad sentinel is 0xFFFFFFFE)
BOUNDARY = 0xFFFFFFFD


# ------------------------------------------------------------- common nets
@pytest.mark.parametrize("n", [2, 8, 64, 256])
def test_bitonic_sort_matches_numpy(n):
    rng = np.random.default_rng(n)
    keys = rng.integers(0, 1000, n).astype(np.uint32)
    payload = np.arange(n, dtype=np.uint32)
    k, p = bitonic_sort(jnp.asarray(keys), jnp.asarray(payload))
    assert_array_equal(np.sort(keys), np.asarray(k))
    # payload follows its key
    assert_array_equal(keys[np.asarray(p)], np.asarray(k))


def test_bitonic_merge_of_two_sorted_runs():
    rng = np.random.default_rng(0)
    a = np.sort(rng.integers(0, 500, 32)).astype(np.uint32)
    b = np.sort(rng.integers(0, 500, 32)).astype(np.uint32)
    seq = np.concatenate([a, b[::-1]]).astype(np.uint32)
    (merged,) = bitonic_merge(jnp.asarray(seq))
    assert_array_equal(np.sort(np.concatenate([a, b])), np.asarray(merged))


# --------------------------------------------------------------- gc_lookup
@pytest.mark.parametrize("q,n", [(1, 10), (17, 100), (300, 1000),
                                 (256, 512), (5, 2000)])
def test_gc_lookup_matches_ref(q, n):
    rng = np.random.default_rng(q * 1000 + n)
    s_keys = np.sort(rng.choice(np.arange(1, 10 * n, dtype=np.uint32),
                                size=n, replace=False))
    s_vids = rng.integers(1, 1 << 30, n).astype(np.uint32)
    s_vf = rng.integers(1, 1 << 20, n).astype(np.uint32)
    queries = np.concatenate([
        rng.choice(s_keys, q // 2 + 1),
        rng.integers(10 * n, 20 * n, q - q // 2 - 1).astype(np.uint32)])[:q]
    got = gc_lookup(queries, s_keys, s_vids, s_vf)
    want = gc_lookup_ref(jnp.asarray(queries), jnp.asarray(s_keys),
                         jnp.asarray(s_vids), jnp.asarray(s_vf))
    for g, w in zip(got, want):
        assert_array_equal(np.asarray(g), np.asarray(w))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 2**20), min_size=1, max_size=200, unique=True),
       st.lists(st.integers(0, 2**20), min_size=1, max_size=100))
def test_gc_lookup_property(skeys, queries):
    s_keys = np.sort(np.array(skeys, np.uint32))
    s_vids = s_keys + 7
    s_vf = s_keys % 97
    q = np.array(queries, np.uint32)
    found, vid, vf = gc_lookup(q, s_keys, s_vids, s_vf)
    member = np.isin(q, s_keys)
    assert_array_equal(np.asarray(found), member)
    assert_array_equal(np.asarray(vid)[member], (q + 7)[member])


# ------------------------------------------------------------------- bloom
@pytest.mark.parametrize("n,q", [(10, 5), (1000, 300), (5000, 1000)])
def test_bloom_probe_matches_ref_and_no_false_negatives(n, q):
    rng = np.random.default_rng(n)
    keys = rng.choice(np.arange(1, 1 << 24, dtype=np.uint32), n,
                      replace=False)
    words, k, nbits = bloom_build(keys)
    probes = np.concatenate([keys[:q // 2],
                             rng.integers(1 << 24, 1 << 25,
                                          q - q // 2).astype(np.uint32)])
    got = np.asarray(bloom_probe(probes, words, k, nbits))
    want = np.asarray(bloom_probe_ref(jnp.asarray(probes), words, k, nbits))
    assert_array_equal(got, want)
    assert got[:q // 2].all(), "bloom false negative!"
    fp = got[q // 2:].mean()
    assert fp < 0.1


# ------------------------------------------------------------------- merge
@pytest.mark.parametrize("na,nb", [(1, 1), (10, 3), (100, 100), (64, 257)])
def test_merge_dedup_matches_ref(na, nb):
    rng = np.random.default_rng(na * 97 + nb)
    ak = np.sort(rng.choice(np.arange(1000, dtype=np.uint32), na,
                            replace=False))
    bk = np.sort(rng.choice(np.arange(1000, dtype=np.uint32), nb,
                            replace=False))
    aseq = rng.integers(0, 1000, na).astype(np.uint32) * 2        # even
    bseq = rng.integers(0, 1000, nb).astype(np.uint32) * 2 + 1    # odd
    avid = rng.integers(0, 1 << 30, na).astype(np.uint32)
    bvid = rng.integers(0, 1 << 30, nb).astype(np.uint32)
    gk, gs, gv, gkeep = merge_dedup(ak, aseq, avid, bk, bseq, bvid)
    wk, ws, wv, wkeep = merge_dedup_ref(
        jnp.asarray(ak), jnp.asarray(aseq), jnp.asarray(avid),
        jnp.asarray(bk), jnp.asarray(bseq), jnp.asarray(bvid))
    # compare surviving rows (sorted by key) — orderings within dup pairs
    # may differ, winners must not
    got = sorted(zip(np.asarray(gk)[np.asarray(gkeep)].tolist(),
                     np.asarray(gs)[np.asarray(gkeep)].tolist(),
                     np.asarray(gv)[np.asarray(gkeep)].tolist()))
    want = sorted(zip(np.asarray(wk)[np.asarray(wkeep)].tolist(),
                      np.asarray(ws)[np.asarray(wkeep)].tolist(),
                      np.asarray(wv)[np.asarray(wkeep)].tolist()))
    assert got == want
    # merged keys are sorted
    assert (np.diff(np.asarray(gk)) >= 0).all()


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=60, unique=True),
       st.lists(st.integers(0, 50), min_size=1, max_size=60, unique=True))
def test_merge_dedup_property_newest_wins(akeys, bkeys):
    ak = np.sort(np.array(akeys, np.uint32))
    bk = np.sort(np.array(bkeys, np.uint32))
    aseq = np.full(len(ak), 10, np.uint32)
    bseq = np.full(len(bk), 20, np.uint32)       # b is newer
    avid = ak + 1
    bvid = bk + 2
    gk, gs, gv, keep = merge_dedup(ak, aseq, avid, bk, bseq, bvid)
    kept = {int(k): int(v) for k, v in
            zip(np.asarray(gk)[np.asarray(keep)],
                np.asarray(gv)[np.asarray(keep)])}
    expect = {int(k): int(k) + 1 for k in ak}
    expect.update({int(k): int(k) + 2 for k in bk})   # newer b wins
    assert kept == expect


# --------------------------------------------------------------- partition
@pytest.mark.parametrize("n", [1, 7, 64, 500])
def test_partition_matches_ref(n):
    rng = np.random.default_rng(n)
    keys = rng.integers(0, 1 << 30, n).astype(np.uint32)
    hot = rng.random(n) < 0.3
    vids = rng.integers(0, 1 << 30, n).astype(np.uint32)
    vsz = rng.integers(1, 1 << 16, n).astype(np.uint32)
    gk, gv, gs, gcnt = hot_cold_partition(keys, hot, vids, vsz)
    wk, wv, ws, wcnt = hot_cold_partition_ref(
        jnp.asarray(keys), jnp.asarray(hot), jnp.asarray(vids),
        jnp.asarray(vsz))
    assert int(gcnt) == int(wcnt) == hot.sum()
    assert_array_equal(np.asarray(gk), np.asarray(wk))
    assert_array_equal(np.asarray(gv), np.asarray(wv))
    assert_array_equal(np.asarray(gs), np.asarray(ws))


# ------------------------------------------------------------ paged gather
@pytest.mark.parametrize("b,p,npages,psize,d,dtype", [
    (1, 1, 4, 8, 128, jnp.float32),
    (4, 8, 64, 16, 128, jnp.float32),
    (2, 4, 16, 8, 64, jnp.bfloat16),
    (3, 5, 32, 4, 256, jnp.int32),
])
def test_page_gather_matches_ref(b, p, npages, psize, d, dtype):
    rng = np.random.default_rng(b * 100 + p)
    pages = jnp.asarray(
        rng.standard_normal((npages, psize, d)) * 10).astype(dtype)
    table = rng.integers(0, npages, (b, p)).astype(np.int32)
    got = page_gather(table, pages)
    want = page_gather_ref(jnp.asarray(table), pages)
    assert got.shape == (b, p * psize, d)
    assert_array_equal(np.asarray(got.astype(jnp.float32)),
                       np.asarray(want.astype(jnp.float32)))


# ----------------------------------------------- lookup_probe (fused read)
def _rank_oracle(queries, table):
    pos = np.searchsorted(table, queries)
    ok = pos < len(table)
    safe = np.where(ok, pos, 0)
    ok &= len(table) > 0 and table[safe] == queries
    return ok, pos


def _bloom_oracle(bit_idx, words):
    w = words[bit_idx >> 5]
    return (((w >> (bit_idx & 31)) & 1) == 1).all(axis=1)


def _probe_case(rng, q, n, boundary=False):
    """Adversarial (queries, table, bit_idx, words) quadruple."""
    space = np.arange(1, 4 * n + 2, dtype=np.uint32)
    table = np.sort(rng.choice(space, n, replace=False))
    if boundary and n:
        table[-1] = BOUNDARY
    queries = np.concatenate([
        rng.choice(table, q // 2 + 1) if n else np.zeros(1, np.uint32),
        rng.integers(4 * n + 2, 8 * n + 9, q).astype(np.uint32)])[:q]
    if boundary and q:
        queries[0] = BOUNDARY
    k, nbits = 7, 1 << 14
    words = rng.integers(0, 1 << 32, nbits // 32, dtype=np.uint64)
    words = words.astype(np.uint32)
    bit_idx = rng.integers(0, nbits, (q, k)).astype(np.uint32)
    return queries, table, bit_idx, words


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("q,n", [(0, 16), (1, 1), (7, 300), (256, 512),
                                 (300, 1000)])
def test_lookup_probe_matches_oracle(q, n, mode):
    if mode == "interpret" and q * n > 4096:
        pytest.skip("interpret mode: small shapes only")
    rng = np.random.default_rng(q * 1000 + n)
    queries, table, bit_idx, words = _probe_case(rng, q, n, boundary=True)
    may, found, rank = lookup_probe(queries, table, bit_idx, words,
                                    mode=mode)
    assert_array_equal(may, _bloom_oracle(bit_idx, words))
    wf, wr = _rank_oracle(queries, table)
    assert_array_equal(found, wf)
    assert_array_equal(rank[found], wr[found])


@pytest.mark.parametrize("mode", MODES)
def test_rank_probe_all_duplicates(mode):
    table = np.array([5, 9, 1000], np.uint32)
    queries = np.full(9, 9, np.uint32)          # all-duplicate batch
    found, rank = rank_probe(queries, table, mode=mode)
    assert found.all() and (rank == 1).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, BOUNDARY), min_size=1, max_size=64,
                unique=True),
       st.lists(st.integers(0, BOUNDARY), min_size=0, max_size=64))
def test_rank_probe_property(tkeys, queries):
    table = np.sort(np.array(tkeys, np.uint32))
    q = np.array(queries, np.uint32)
    found, rank = rank_probe(q, table, mode="xla")
    wf, wr = _rank_oracle(q, table)
    assert_array_equal(found, wf)
    assert_array_equal(rank[found], wr[found])


@pytest.mark.parametrize("mode", MODES)
def test_interval_rank_matches_assign_files(mode):
    # disjoint sorted [min, max] file ranges, like an LSM level
    mins = np.array([10, 40, 100, 1000], np.uint64)
    maxs = np.array([30, 60, 900, BOUNDARY], np.uint64)
    queries = np.array([0, 10, 30, 31, 40, 99, 100, 900, 901, 1000,
                        BOUNDARY], np.uint64)
    got = interval_rank(queries, mins, maxs, mode=mode)
    pos = np.searchsorted(mins, queries, side="right") - 1
    ok = pos >= 0
    safe = np.where(ok, pos, 0)
    ok &= queries <= maxs[safe]
    assert_array_equal(got, np.where(ok, pos, -1))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=2, max_size=40,
                unique=True),
       st.lists(st.integers(0, 11_000), min_size=1, max_size=50))
def test_interval_rank_property(bounds, queries):
    e = np.sort(np.array(bounds, np.uint64))
    mins, maxs = e[::2][:len(e) // 2], e[1::2][:len(e) // 2]
    q = np.array(queries, np.uint64)
    got = interval_rank(q, mins, maxs, mode="xla")
    for qi, gi in zip(q.tolist(), got.tolist()):
        covers = np.nonzero((mins <= qi) & (qi <= maxs))[0]
        assert gi == (covers[0] if len(covers) else -1)


# -------------------------------------------- run_coalesce (fetch planning)
def _coalesce_oracle(rank, pos, window):
    """Per-rank np.unique + adjacency split + window chunking — the host
    planner in core/values/fetch.py."""
    from repro.core.values.fetch import split_runs
    out = []
    for r in np.unique(rank):
        posu = np.unique(pos[rank == r])
        out.append((int(r), [c.tolist()
                             for c in split_runs(posu, window)]))
    return out


def _runs_from_kernel(rank_s, pos_s, keep, start):
    out = []
    for r in np.unique(rank_s[keep]):
        sel = keep & (rank_s == r)
        runs = np.split(pos_s[sel], np.nonzero(start[sel])[0][1:])
        out.append((int(r), [c.tolist() for c in runs]))
    return out


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("window", [None, 1, 3, 16])
@pytest.mark.parametrize("case", ["empty", "single", "dups", "mixed"])
def test_run_coalesce_matches_host_planner(case, window, mode):
    rng = np.random.default_rng(hash((case, window)) % (1 << 32))
    if case == "empty":
        rank = pos = np.zeros(0, np.int64)
    elif case == "single":
        rank, pos = np.array([3]), np.array([77])
    elif case == "dups":
        rank = np.zeros(12, np.int64)
        pos = np.full(12, 5, np.int64)          # all-duplicate positions
    else:
        m = 100 if mode == "interpret" else 700   # non-tile-multiple
        rank = rng.integers(0, 5, m)
        pos = rng.integers(0, 40, m)
    got = run_coalesce(rank, pos, window=window, mode=mode)
    assert _runs_from_kernel(*got) == _coalesce_oracle(rank, pos, window)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 30)),
                min_size=1, max_size=80),
       st.sampled_from([None, 1, 2, 7]))
def test_run_coalesce_property(pairs, window):
    rank = np.array([p[0] for p in pairs], np.int64)
    pos = np.array([p[1] for p in pairs], np.int64)
    got = run_coalesce(rank, pos, window=window, mode="xla")
    assert _runs_from_kernel(*got) == _coalesce_oracle(rank, pos, window)


# -------------------------------------------- segment_reduce (adaptive)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("m,slots", [(0, 8), (1, 1), (13, 7), (300, 64),
                                     (100, 1000)])
def test_segment_sum_matches_bincount(m, slots, mode):
    if mode == "interpret" and slots > 64:
        pytest.skip("interpret mode: small shapes only")
    rng = np.random.default_rng(m * 31 + slots)
    ids = rng.integers(-1, slots + 2, m)        # includes out-of-range
    got = segment_sum(ids, slots, mode=mode)
    valid = ids[(ids >= 0) & (ids < slots)]
    assert_array_equal(got, np.bincount(valid, minlength=slots))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=0, max_size=200),
       st.sampled_from([1, 17, 64]))
def test_segment_sum_property(ids, slots):
    a = np.array(ids, np.int64)
    got = segment_sum(a, slots, mode="xla")
    valid = a[a < slots]
    assert_array_equal(got, np.bincount(valid, minlength=slots))


def _min64_oracle(vals, idx):
    est = vals[0][idx[:, 0]]
    for r in range(1, vals.shape[0]):
        est = np.minimum(est, vals[r][idx[:, r]])
    return est


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("d,w,q", [(1, 1, 1), (2, 50, 33), (4, 100, 64)])
def test_gather_min64_reconstructs_f64_min(d, w, q, mode):
    rng = np.random.default_rng(d * 100 + w + q)
    vals = (rng.random((d, w)) * 1e6)           # non-negative f64
    vals[rng.random((d, w)) < 0.2] = 0.0
    idx = rng.integers(0, w, (q, d))
    v = vals.view(np.uint32).reshape(d, w, 2)
    oh, ol = gather_min64(v[..., 1], v[..., 0], idx, mode=mode)
    got = ((oh.astype(np.uint64) << np.uint64(32))
           | ol.astype(np.uint64)).view(np.float64)
    assert_array_equal(got, _min64_oracle(vals, idx))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.0, 1e12, allow_nan=False), min_size=2,
                max_size=40))
def test_gather_min64_property(vals_flat):
    w = len(vals_flat) // 2
    vals = np.array(vals_flat[:2 * w], np.float64).reshape(2, w)
    idx = np.stack([np.arange(w), np.arange(w)], axis=1)
    v = vals.view(np.uint32).reshape(2, w, 2)
    oh, ol = gather_min64(v[..., 1], v[..., 0], idx, mode="xla")
    got = ((oh.astype(np.uint64) << np.uint64(32))
           | ol.astype(np.uint64)).view(np.float64)
    assert_array_equal(got, np.minimum(vals[0], vals[1]))
