PY := python
export PYTHONPATH := src

.PHONY: check test lint analyze bench-smoke trace

check: lint test bench-smoke

test:
	$(PY) -m pytest -x -q

lint: analyze
	@$(PY) -m ruff --version >/dev/null 2>&1 || \
		{ echo "ruff not installed (pip install ruff)"; exit 1; }
	$(PY) -m ruff check src tests benchmarks

# scavlint: project-specific architectural invariants (DESIGN.md §10)
analyze:
	$(PY) -m repro.analysis src benchmarks examples tests

bench-smoke:
	REPRO_BENCH_SCALE=quick $(PY) -m benchmarks.run \
		--trace=trace_out batch_api read_path \
		sharding adaptive_gc recovery elasticity fig02_tradeoff \
		fig05_spaceamp_sources kernels_bench
	$(PY) -m repro.obs check trace_out
	$(PY) -m benchmarks.perf_report --gate

# Perfetto-viewable observability dump from the fig02 workload
# (+ read_path for the multi_get tail, fig05 for the cause ledger)
# — DESIGN.md §11, §13
trace:
	REPRO_BENCH_SCALE=quick $(PY) -m benchmarks.run \
		--trace=trace_out fig02_tradeoff read_path \
		fig05_spaceamp_sources
	$(PY) -m repro.obs check trace_out
	$(PY) -m repro.obs blame trace_out
	$(PY) -m repro.obs summarize trace_out
	@echo "open trace_out/*/trace.json in https://ui.perfetto.dev"
