PY := python
export PYTHONPATH := src

.PHONY: check test bench-smoke

check: test bench-smoke

test:
	$(PY) -m pytest -x -q

bench-smoke:
	REPRO_BENCH_SCALE=quick $(PY) -m benchmarks.run batch_api sharding \
		fig02_tradeoff
