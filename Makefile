PY := python
export PYTHONPATH := src

.PHONY: check test lint analyze bench-smoke

check: lint test bench-smoke

test:
	$(PY) -m pytest -x -q

lint: analyze
	@$(PY) -m ruff --version >/dev/null 2>&1 || \
		{ echo "ruff not installed (pip install ruff)"; exit 1; }
	$(PY) -m ruff check src tests benchmarks

# scavlint: project-specific architectural invariants (DESIGN.md §10)
analyze:
	$(PY) -m repro.analysis src benchmarks examples tests

bench-smoke:
	REPRO_BENCH_SCALE=quick $(PY) -m benchmarks.run batch_api read_path \
		sharding adaptive_gc recovery fig02_tradeoff
